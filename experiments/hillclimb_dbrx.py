import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf pair B driver: dbrx-132b x train_4k variants (worst useful-flops
# fraction). Compiles each variant on the production mesh and reports
# compiled memory/collectives + analytic roofline terms.
import json
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build, MICROBATCH
from benchmarks import costmodel as cm

mesh = make_production_mesh()
base = get_config("dbrx-132b")
variants = {
    "baseline_cf1.25": {},
    "cf1.0": dict(moe_capacity_factor=1.0),
    "cf1.0_dots_remat": dict(moe_capacity_factor=1.0, remat_policy="dots"),
}
out = {}
for name, kw in variants.items():
    cfg = base.with_overrides(**kw)
    step, inputs, cfg2 = build(cfg, "train_4k", mesh)
    c = jax.jit(step).lower(*inputs).compile()
    mem = c.memory_analysis()
    from repro.launch.dryrun import collective_bytes
    coll = collective_bytes(c.as_text())
    r = cm.analyze(cfg, "train_4k", "single",
                   microbatch=MICROBATCH["dbrx-132b"])
    t = r.terms()
    out[name] = {
        "analytic_compute_s": t["compute_s"],
        "analytic_memory_s": t["memory_s"],
        "analytic_collective_s": t["collective_s"],
        "useful_flops_fraction": r.model_flops / (r.flops * 256),
        "compiled_mem_GiB": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes) / 2**30,
        "compiled_coll_GiB": coll["total_bytes"] / 2**30,
        "compiled_flops_per_dev": c.cost_analysis()["flops"],
    }
    print(name, json.dumps(out[name], indent=1), flush=True)

with open("experiments/hillclimb_dbrx.json", "w") as f:
    json.dump(out, f, indent=1)
